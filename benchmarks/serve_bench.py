"""Serving-subsystem benchmark (``python -m benchmarks.run --serve``).

Two sections, both recorded in the standardized ``BENCH_serve.json``
artifact (schema ``ggpu-serve/1``, path overridable via
``GGPU_SERVE_OUT``):

  * **throughput** — a bursty same-kernel trace served through the
    continuous-batching ``Scheduler`` (submit interleaved with
    incremental drains). Reports launches/sec (warm wall-clock, compile
    excluded), batch occupancy (launches per compiled-stepper dispatch),
    and the executor trace-cache hit rate — repeat traffic must not
    re-trace.
  * **fleet** — the routing demo connecting the DSE output to the serving
    path: a mixed wide+narrow trace is served across two configs picked
    from a ``repro.dse.search`` Pareto front, and the routed fleet's
    modeled makespan is compared against pinning the whole trace to
    either single config.

``--fast`` shrinks the trace and the DSE grid (the CI ``serve-smoke``
job).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

SCHEMA = "ggpu-serve/1"


def _bursty_mems(b, k, rng):
    """k fresh memory images for bench ``b`` (same envelope, new data)."""
    n = b.gpu_mem.shape[0]
    return [np.concatenate([rng.integers(-100, 100,
                                         2 * b.gpu_n).astype(np.int32),
                            np.zeros(n - 2 * b.gpu_n, np.int32)])
            for _ in range(k)]


def bench_throughput(emit, fast: bool) -> dict:
    from repro.ggpu import programs
    from repro.ggpu.engine import GGPUConfig
    from repro.serve import Scheduler

    cfg = GGPUConfig(n_cus=2)
    b = programs._vec_mul(32, 1024 if fast else 4096)
    burst = 4 if fast else 8
    n_bursts = 2 if fast else 4
    rng = np.random.default_rng(0)
    sched = Scheduler(cfg)
    for m in _bursty_mems(b, burst, rng):
        sched.submit(b.gpu_prog, m, b.gpu_items)
    sched.drain()                            # warm-up: pay the jit compile
    st = sched.executor.stats
    l0, d0 = st.launches, st.dispatches
    h0, m0 = st.trace_hits, st.trace_misses
    t0 = time.perf_counter()
    served = 0
    for _ in range(n_bursts):                # submissions interleave drains
        for m in _bursty_mems(b, burst, rng):
            sched.submit(b.gpu_prog, m, b.gpu_items)
        served += len(sched.drain())
    wall = time.perf_counter() - t0
    hits = st.trace_hits - h0
    misses = st.trace_misses - m0
    row = {
        "device": f"{cfg.n_cus}cu/{cfg.memsys}",
        "kernel": b.name,
        "launches": served,
        "wall_s": round(wall, 4),
        "launches_per_sec": round(served / wall, 2),
        "batch_occupancy": round((st.launches - l0)
                                 / (st.dispatches - d0), 3),
        "executor_cache": {"hits": hits, "misses": misses,
                           "hit_rate": round(hits / (hits + misses), 3)
                           if hits + misses else 0.0},
    }
    emit("serve/throughput", wall / served * 1e6,
         f"launches_per_sec={row['launches_per_sec']} "
         f"occupancy={row['batch_occupancy']} "
         f"cache_hit_rate={row['executor_cache']['hit_rate']}")
    return row


def bench_fleet(emit, fast: bool) -> dict:
    from repro import dse
    from repro.ggpu import programs
    from repro.serve import Fleet, pinned_makespan

    # DSE-selected devices: the (fastest, smallest) ends of a Pareto front
    if fast:
        specs = dse.enumerate_specs(cus=(1, 8), freq_targets=(667.0,))
        ev = dse.Evaluator(benches=("xcorr",), sizes={"xcorr": (16, 128)})
    else:
        specs = dse.enumerate_specs(cus=(1, 2, 4, 8),
                                    freq_targets=(500.0, 667.0))
        ev = dse.Evaluator(benches=("xcorr",), sizes={"xcorr": (32, 256)})
    res = dse.search(specs=specs, evaluator=ev)
    frontier = sorted(res.frontier, key=lambda p: p.time_us)
    picks = [frontier[0], frontier[-1]]
    if picks[0] is picks[1]:
        raise RuntimeError("DSE frontier collapsed to one design: nothing "
                           "to route across — widen the spec grid")
    devices = [(p.label(), p.point.config) for p in picks]

    wide = programs._copy(16, 1024 if fast else 4096)      # many wavefronts
    narrow = programs._reduction(64, 256 if fast else 1024)  # W=1
    rng = np.random.default_rng(1)
    trace = []
    for _ in range(3 if fast else 8):
        trace.append((wide.gpu_prog, _bursty_mems(wide, 1, rng)[0],
                      wide.gpu_items))
        trace.append((narrow.gpu_prog, _bursty_mems(narrow, 1, rng)[0],
                      narrow.gpu_items))

    fleet = Fleet(devices)
    for prog, mem0, n_items in trace:
        fleet.submit(prog, mem0, n_items)
    fleet.drain()
    rep = fleet.report()
    pinned = {name: round(pinned_makespan(cfg, trace), 3)
              for name, cfg in devices}
    best_pin = min(pinned.values())
    rep.update({
        "pinned_us": pinned,
        "speedup_vs_best_pin": round(best_pin / rep["makespan_us"], 3),
        "beats_both_pins": rep["makespan_us"] < best_pin,
    })
    emit("serve/fleet/makespan", rep["makespan_us"],
         f"devices={'+'.join(rep['devices'])} "
         f"placement={rep['placement']} "
         f"pinned_us={pinned} speedup={rep['speedup_vs_best_pin']}x")
    return rep


def invariant_problems(art: dict) -> list:
    """Smoke invariants a healthy serve run must satisfy — checked by
    ``benchmarks.run`` after the artifact is written so a broken result
    fails the build instead of uploading quietly."""
    problems = []
    fleet = art.get("fleet", {})
    if not fleet.get("beats_both_pins"):
        problems.append(
            "fleet.beats_both_pins: routing does not beat both pinned "
            f"configs (makespan={fleet.get('makespan_us')} "
            f"pinned={fleet.get('pinned_us')})")
    if art.get("cache_hit_rate", 0) <= 0:
        problems.append("cache_hit_rate: executor trace-cache hit rate "
                        "is 0 — repeat traffic is re-tracing")
    if art.get("batch_occupancy", 0) <= 1:
        problems.append(
            f"batch occupancy {art.get('batch_occupancy')} <= 1: the "
            "scheduler is not folding same-kernel launches")
    if fleet.get("quarantined"):
        problems.append(
            f"fleet quarantined launches: {fleet['quarantined']}")
    return problems


def bench_serve(emit, fast: bool = False, out: str = None) -> dict:
    """Run both sections and write the ``BENCH_serve.json`` artifact;
    returns the artifact dict."""
    out = out or os.environ.get("GGPU_SERVE_OUT", "BENCH_serve.json")
    throughput = bench_throughput(emit, fast)
    fleet = bench_fleet(emit, fast)
    art = {
        "schema": SCHEMA,
        "launches_per_sec": throughput["launches_per_sec"],
        "batch_occupancy": throughput["batch_occupancy"],
        "cache_hit_rate": throughput["executor_cache"]["hit_rate"],
        "throughput": throughput,
        "fleet": fleet,
    }
    with open(out, "w") as f:
        json.dump(art, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("serve/artifact", 0.0, f"wrote {out}")
    return art
