"""Perf-regression gate: compare fresh bench artifacts against baselines.

CI runs the ``dse-smoke`` / ``serve-smoke`` jobs, then::

    python -m benchmarks.check_bench BENCH_dse.json \
        benchmarks/baselines/BENCH_dse.json

and fails the build on any violation, so a perf regression breaks CI
instead of uploading quietly. The artifact kind is auto-detected from the
``schema`` field (``ggpu-dse/1`` / ``ggpu-serve/4`` / ``ggpu-compiler/2``
/ ``ggpu-resilience/1`` — the resilience gate re-enforces the chaos
invariants and compares the deterministic fault counts exactly;
the compiler gate also re-enforces the absolute autotune invariants on
the fresh artifact: tuned never worse than the default schedule anywhere,
strictly better on >= 1 bench, all candidates oracle-verified). A fresh
serve artifact carrying ``"sections": ["graph"]`` (the partial output of
``benchmarks.run --graph``, the CI ``graph-smoke`` job) is gated on its
graph section only — absolute invariants (bit-exact, pipelined >=
GRAPH_MIN_SPEEDUP over the host-staged baseline, one dispatch per stage)
plus bands against the full committed serve baseline.

Tolerance bands per metric class:

  * **exact** — simulator cycle counts, Pareto frontier membership,
    batch occupancy, executor cache hit rate, and the
    ``beats_both_pins`` routing invariant. These are deterministic
    functions of the committed code; any drift is a real behavior change.
  * **modeled time, ±25 % (``--tol``)** — modeled wall-clock/throughput
    derived as cycles/fmax (``time_us``, ``makespan_us``,
    ``pinned_us``). Deterministic too, but banded so intentional small
    model changes (e.g. a new PPA coefficient) need only a baseline
    refresh, not a same-commit lockstep.
  * **host wall-clock, ×4 band (``--host-tol``)** — raw machine timings
    (``launches_per_sec``, ``wall_s``, ``sim_wall_s``). These measure the
    *simulator's* speed on whatever runner executed the job; across
    runner generations they legitimately vary far beyond the modeled-time
    band, so the default band is a generous ratio. Tighten with
    ``--host-tol 0.25`` when baselines are produced on pinned hardware.

Library use: ``check_artifacts(fresh, baseline, ...) -> [violations]``
(see ``tests/test_check_bench.py``, which demonstrates that an injected
cycle regression fails the gate).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

DSE_SCHEMA = "ggpu-dse/1"
SERVE_SCHEMA = "ggpu-serve/4"
COMPILER_SCHEMA = "ggpu-compiler/2"
RESILIENCE_SCHEMA = "ggpu-resilience/1"


def _band(violations: List[str], name: str, fresh, base, tol: float):
    """Relative band check: |fresh - base| <= tol * |base|."""
    if base is None or fresh is None:
        violations.append(f"{name}: missing (fresh={fresh}, base={base})")
        return
    if base == 0:
        if fresh != 0:
            violations.append(f"{name}: baseline 0, fresh {fresh}")
        return
    rel = abs(fresh - base) / abs(base)
    if rel > tol:
        violations.append(
            f"{name}: {fresh} vs baseline {base} "
            f"({rel * 100:.1f}% > {tol * 100:.0f}% band)")


def _ratio_band(violations: List[str], name: str, fresh, base,
                tol: float):
    """Symmetric ratio band for host wall-clock metrics: fails when the
    fresh value is more than (1 + tol)x the baseline in either direction
    (a plain relative band can never flag a slowdown beyond -100%)."""
    if base is None or fresh is None:
        violations.append(f"{name}: missing (fresh={fresh}, base={base})")
        return
    if base <= 0 or fresh <= 0:
        if fresh != base:
            violations.append(f"{name}: {fresh} vs baseline {base}")
        return
    ratio = max(fresh / base, base / fresh)
    if ratio > 1 + tol:
        violations.append(
            f"{name}: {fresh} vs baseline {base} "
            f"({ratio:.2f}x > {1 + tol:.2f}x band)")


def _exact(violations: List[str], name: str, fresh, base):
    if fresh != base:
        violations.append(f"{name}: {fresh!r} != baseline {base!r}")


def check_dse(fresh: dict, base: dict, tol: float,
              host_tol: float) -> List[str]:
    v: List[str] = []
    _exact(v, "schema", fresh.get("schema"), base.get("schema"))
    fb, bb = fresh.get("benches", {}), base.get("benches", {})
    _exact(v, "bench set", sorted(fb), sorted(bb))
    for name in sorted(set(fb) & set(bb)):
        _exact(v, f"benches.{name}.cycles", fb[name].get("cycles"),
               bb[name].get("cycles"))
        _band(v, f"benches.{name}.time_us", fb[name].get("time_us"),
              bb[name].get("time_us"), tol)
        _ratio_band(v, f"benches.{name}.sim_wall_s",
                    fb[name].get("sim_wall_s"),
                    bb[name].get("sim_wall_s"), host_tol)
    for key in ("frontier", "analytic_frontier", "excluded_analytic"):
        _exact(v, key, sorted(fresh.get(key, [])),
               sorted(base.get(key, [])))
    return v


def check_serve_graph(fresh: dict, base: dict, tol: float,
                      host_tol: float) -> List[str]:
    """The ``graph`` section's own gate: absolute invariants on the fresh
    artifact (bit-exactness, >= GRAPH_MIN_SPEEDUP, one dispatch per
    stage) plus banded comparison against the committed baseline. Also
    the whole check for a partial ``--graph`` smoke artifact."""
    from benchmarks.serve_bench import graph_invariant_problems

    v: List[str] = []
    _exact(v, "schema", fresh.get("schema"), base.get("schema"))
    v += graph_invariant_problems(fresh)
    _graph_vs_baseline(v, fresh, base, host_tol)
    return v


def _graph_vs_baseline(v: List[str], fresh: dict, base: dict,
                       host_tol: float) -> None:
    """Banded/exact comparison of the ``graph`` section vs the committed
    baseline (shared by the full-artifact and partial-artifact gates)."""
    fg, bg = fresh.get("graph", {}), base.get("graph", {})
    _exact(v, "graph.bit_exact", fg.get("bit_exact"),
           bg.get("bit_exact"))
    _exact(v, "graph.stages", fg.get("stages"), bg.get("stages"))
    _exact(v, "graph.pipelined.dispatches",
           fg.get("pipelined", {}).get("dispatches"),
           bg.get("pipelined", {}).get("dispatches"))
    # host wall-clock metrics: generous ratio bands (runner-dependent)
    _ratio_band(v, "graph.speedup", fg.get("speedup"),
                bg.get("speedup"), host_tol)
    for path in ("pipelined", "host_staged"):
        _ratio_band(v, f"graph.{path}.chains_per_sec",
                    fg.get(path, {}).get("chains_per_sec"),
                    bg.get(path, {}).get("chains_per_sec"), host_tol)


def check_serve(fresh: dict, base: dict, tol: float,
                host_tol: float) -> List[str]:
    from benchmarks.serve_bench import invariant_problems

    if fresh.get("sections") == ["graph"]:
        # partial artifact from ``benchmarks.run --graph`` (graph-smoke):
        # gate only the graph section against the full baseline
        return check_serve_graph(fresh, base, tol, host_tol)

    v: List[str] = []
    _exact(v, "schema", fresh.get("schema"), base.get("schema"))
    # absolute health invariants: one definition, shared with the
    # benchmark harness's own exit-code check (benchmarks.run --serve).
    # This includes the async-beats-sync gate, the sharded bit-exactness
    # gate, the >= SHARDED_MIN_SPEEDUP gate (enforced only when the fresh
    # run had >= 8 simulated devices — the fleet-smoke job), and the
    # open-loop latency sanity checks.
    v += invariant_problems(fresh)
    _exact(v, "batch_occupancy", fresh.get("batch_occupancy"),
           base.get("batch_occupancy"))
    _exact(v, "cache_hit_rate", fresh.get("cache_hit_rate"),
           base.get("cache_hit_rate"))
    _ratio_band(v, "sync_launches_per_sec",
                fresh.get("sync_launches_per_sec"),
                base.get("sync_launches_per_sec"), host_tol)
    _ratio_band(v, "cold_trace_s", fresh.get("cold_trace_s"),
                base.get("cold_trace_s"), host_tol)
    _band(v, "fleet.makespan_us", fresh.get("fleet", {}).get("makespan_us"),
          base.get("fleet", {}).get("makespan_us"), tol)
    fp = fresh.get("fleet", {}).get("pinned_us", {})
    bp = base.get("fleet", {}).get("pinned_us", {})
    _exact(v, "fleet.pinned device set", sorted(fp), sorted(bp))
    for dev in sorted(set(fp) & set(bp)):
        _band(v, f"fleet.pinned_us.{dev}", fp[dev], bp[dev], tol)
    _ratio_band(v, "launches_per_sec", fresh.get("launches_per_sec"),
                base.get("launches_per_sec"), host_tol)
    # sharded throughput compares against baseline only when both runs
    # actually sharded (the single-device serve-smoke job legitimately
    # sees no speedup; the invariants above still enforce bit-exactness)
    fs, bs = fresh.get("sharded", {}), base.get("sharded", {})
    if fresh.get("n_devices", 1) > 1 and base.get("n_devices", 1) > 1:
        _ratio_band(v, "sharded.launches_per_sec",
                    fs.get("sharded", {}).get("launches_per_sec"),
                    bs.get("sharded", {}).get("launches_per_sec"),
                    host_tol)
        _band(v, "sharded.speedup", fs.get("speedup"),
              bs.get("speedup"), host_tol)
    fl, bl = fresh.get("latency", {}), base.get("latency", {})
    _ratio_band(v, "latency.p50_ms", fl.get("p50_ms"), bl.get("p50_ms"),
                host_tol)
    _ratio_band(v, "latency.p99_ms", fl.get("p99_ms"), bl.get("p99_ms"),
                host_tol)
    _ratio_band(v, "latency.rate_per_s", fl.get("rate_per_s"),
                bl.get("rate_per_s"), host_tol)
    _graph_vs_baseline(v, fresh, base, host_tol)
    return v


def check_resilience(fresh: dict, base: dict, tol: float,
                     host_tol: float) -> List[str]:
    """The chaos-resilience gate: absolute invariants on the fresh
    artifact (served-correctly floor, zero silent corruption, eviction
    fired, hedged p99 beats unhedged) plus stability vs the baseline.
    Fault decisions are pure hashes of (seed, kind, ticket, attempt), so
    the seu/device-loss counts are deterministic at the committed seed
    and compared exactly; wall-clock metrics get host ratio bands."""
    from benchmarks.resilience_bench import invariant_problems

    v: List[str] = []
    _exact(v, "schema", fresh.get("schema"), base.get("schema"))
    v += invariant_problems(fresh)
    fs, bs = fresh.get("seu", {}), base.get("seu", {})
    for key in ("n", "seed", "served", "served_correct", "quarantined",
                "silently_corrupted", "injections"):
        _exact(v, f"seu.{key}", fs.get(key), bs.get(key))
    _ratio_band(v, "seu.goodput_ratio", fs.get("goodput_ratio"),
                bs.get("goodput_ratio"), host_tol)
    fd, bd = fresh.get("device_loss", {}), base.get("device_loss", {})
    for key in ("n", "seed", "served", "lost", "quarantined", "evicted",
                "bit_exact", "device_state"):
        _exact(v, f"device_loss.{key}", fd.get(key), bd.get(key))
    ft, bt = fresh.get("straggler", {}), base.get("straggler", {})
    _exact(v, "straggler.n", ft.get("n"), bt.get("n"))
    for leg in ("hedged", "unhedged"):
        _ratio_band(v, f"straggler.{leg}.p99_ms",
                    ft.get(leg, {}).get("p99_ms"),
                    bt.get(leg, {}).get("p99_ms"), host_tol)
    return v


def check_compiler(fresh: dict, base: dict, tol: float,
                   host_tol: float) -> List[str]:
    from benchmarks.compiler_bench import autotune_invariants

    v: List[str] = []
    _exact(v, "schema", fresh.get("schema"), base.get("schema"))
    # suite parity: compiled cycle counts are deterministic goldens
    fp, bp = fresh.get("suite_parity", {}), base.get("suite_parity", {})
    _exact(v, "suite bench set", sorted(fp), sorted(bp))
    for name in sorted(set(fp) & set(bp)):
        for key in ("cycles_hand", "cycles_dsl", "bit_exact", "prog_len"):
            _exact(v, f"suite_parity.{name}.{key}", fp[name].get(key),
                   bp[name].get(key))
    # autotune: absolute invariants on the FRESH artifact (tuned never
    # worse than default, strictly better somewhere, all verified) ...
    ft = fresh.get("autotune", {})
    v += autotune_invariants(ft)
    # ... plus exact chosen-schedule/cycle stability vs the baseline: a
    # tuned-cycle regression or a different deterministic pick is a real
    # compiler behavior change, not noise
    bt = base.get("autotune", {})
    fb, bb = ft.get("benches", {}), bt.get("benches", {})
    _exact(v, "autotune bench set", sorted(fb), sorted(bb))
    for name in sorted(set(fb) & set(bb)):
        for key in ("best_schedule", "default_cycles", "tuned_cycles",
                    "n_candidates"):
            _exact(v, f"autotune.{name}.{key}", fb[name].get(key),
                   bb[name].get(key))
    # codesign: the joint frontier is a deterministic function of the code
    fc, bc = fresh.get("codesign", {}), base.get("codesign", {})
    if not fc.get("frontier"):
        v.append("codesign frontier is empty")
    _exact(v, "codesign.schedules", fc.get("schedules"),
           bc.get("schedules"))
    _exact(v, "codesign.n_points", fc.get("n_points"), bc.get("n_points"))
    _exact(v, "codesign.frontier",
           [(r.get("label"), r.get("schedule"))
            for r in fc.get("frontier", [])],
           [(r.get("label"), r.get("schedule"))
            for r in bc.get("frontier", [])])
    # the nested generated-workload DSE artifact is a standard ggpu-dse/1
    v += [f"dse.{x}" for x in check_dse(fresh.get("dse", {}),
                                        base.get("dse", {}), tol,
                                        host_tol)]
    return v


def check_artifacts(fresh: dict, base: dict, tol: float = 0.25,
                    host_tol: float = 3.0,
                    section: Optional[str] = None) -> List[str]:
    """All violations of ``fresh`` against ``base`` (empty = gate passes).
    ``section="graph"`` restricts a serve check to the graph section —
    the ``benchmarks.run --graph`` partial artifact (which also carries a
    ``sections`` marker that triggers the same restriction)."""
    schema = base.get("schema")
    if schema == DSE_SCHEMA:
        return check_dse(fresh, base, tol, host_tol)
    if schema == SERVE_SCHEMA:
        if section == "graph":
            return check_serve_graph(fresh, base, tol, host_tol)
        if section is not None:
            return [f"unknown serve section {section!r}"]
        return check_serve(fresh, base, tol, host_tol)
    if schema == COMPILER_SCHEMA:
        return check_compiler(fresh, base, tol, host_tol)
    if schema == RESILIENCE_SCHEMA:
        if section not in (None, "resilience"):
            return [f"unknown resilience section {section!r}"]
        return check_resilience(fresh, base, tol, host_tol)
    return [f"unknown baseline schema {schema!r}"]


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Fail when a fresh bench artifact regresses vs its "
                    "committed baseline.")
    ap.add_argument("fresh", help="freshly produced artifact (JSON)")
    ap.add_argument("baseline", help="committed baseline artifact (JSON)")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="relative band for modeled wall-clock metrics "
                         "(default 0.25)")
    ap.add_argument("--host-tol", type=float, default=3.0,
                    help="relative band for raw host wall-clock metrics "
                         "(default 3.0 — simulator speed varies across "
                         "runners)")
    ap.add_argument("--section", default=None,
                    help="gate only one section of an artifact "
                         "(graph — the graph-smoke job's partial "
                         "BENCH_graph.json; resilience — the "
                         "resilience-smoke job's BENCH_resilience.json)")
    args = ap.parse_args(argv)
    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)
    violations = check_artifacts(fresh, base, args.tol, args.host_tol,
                                 section=args.section)
    if violations:
        print(f"{len(violations)} bench regression(s) vs {args.baseline}:",
              file=sys.stderr)
        for v in violations:
            print(f"  - {v}", file=sys.stderr)
        return 1
    print(f"bench gate OK: {args.fresh} within bands of {args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
