"""Paper tables/figures from the G-GPU reproduction.

  table1_ppa      — Table I: 12 versions PPA (ours vs paper, rel. error)
  table2_wires    — Table II analogue: interconnect delay / achieved fmax
  table3_cycles   — Table III: 7 kernels x {RISC-V, 1/2/4/8 CU} cycles
  fig5_speedup    — Fig 5: raw speed-up over RISC-V (input-ratio scaled)
  fig6_area      — Fig 6: speed-up derated by area ratio
  table_memsys   — beyond the paper: cache-organization DSE on the
                   cache-thrashing kernel (xcorr), shared vs banked

Each emits ``name,us_per_call,derived`` CSV rows (us_per_call = simulated
wall-time at the version's achieved frequency where applicable).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.planner import enumerate_versions, plan
from repro.core.ppa import PAPER_TABLE1
from repro.ggpu.machine import GGPUConfig, ScalarConfig, run_kernel
from repro.ggpu.programs import PAPER_CYCLES, PAPER_INPUT, all_benches

RISCV_AREA_MM2 = 4.19 / 6.5     # paper: 1-CU G-GPU is 6.5x the RISC-V area
RISCV_FREQ = 667.0

_cycle_cache = {}


def _ggpu_freqs():
    """Achieved post-layout frequency per CU count at the 667 target
    (planner: 8 CU derates to ~600 MHz)."""
    out = {}
    for c in (1, 2, 4, 8):
        p = plan(c, 667.0)
        out[c] = p.version.fmax_mhz() if not p.achieved else 667.0
    return out


def simulate_all(verbose=False):
    """Cycle-simulate every paper kernel on RISC-V and 1/2/4/8-CU G-GPUs
    (extension benches like ``reduction`` have no paper column and are
    covered by tests/serve benchmarks instead)."""
    if _cycle_cache:
        return _cycle_cache
    benches = {n: b for n, b in all_benches().items() if n in PAPER_CYCLES}
    for name, b in benches.items():
        t0 = time.time()
        mem, si = run_kernel(b.scalar_prog, b.scalar_mem, 1, ScalarConfig())
        assert np.array_equal(mem[b.scalar_out], b.ref(b.scalar_mem,
                                                       b.scalar_n)), name
        row = {"riscv": si["cycles"]}
        for ncu in (1, 2, 4, 8):
            mem, gi = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items,
                                 GGPUConfig(n_cus=ncu))
            assert np.array_equal(mem[b.gpu_out],
                                  b.ref(b.gpu_mem, b.gpu_n)), name
            row[ncu] = gi["cycles"]
        _cycle_cache[name] = row
        if verbose:
            print(f"# simulated {name} in {time.time() - t0:.0f}s: {row}")
    return _cycle_cache


def table1_ppa(emit):
    for p in enumerate_versions():
        r = p.version.report()
        req = 500 if r["fmax_mhz"] <= 520 else (590 if r["fmax_mhz"] <= 610
                                                and r["pipelines"] <= 1 else 667)
        # match request by construction: versions come in freq-major order
    plans = enumerate_versions()
    reqs = [500] * 4 + [590] * 4 + [667] * 4
    for p, req in zip(plans, reqs):
        r = p.version.report()
        pap = PAPER_TABLE1[(r["n_cus"], req)]
        err = abs(r["total_area_mm2"] - pap["area"]) / pap["area"]
        emit(f"table1/{r['n_cus']}cu@{req}", 0.0,
             f"area={r['total_area_mm2']} paper={pap['area']} "
             f"err={err:.1%} mem_blocks={r['n_memory']}(paper {pap['mem']}) "
             f"totW={r['total_w']}(paper {pap['total']}) "
             f"fmax={r['fmax_mhz']} achieved={p.achieved}")


def table2_wires(emit):
    for c in (1, 2, 4, 8):
        p = plan(c, 667.0)
        v = p.version
        emit(f"table2/interconnect_{c}cu", 0.0,
             f"ic_delay_ns={v.interconnect_ns():.3f} "
             f"fmax_mhz={v.fmax_mhz():.0f} "
             f"paper_layout={'600 (derated)' if c == 8 else '667'}")


def table3_cycles(emit):
    cyc = simulate_all()
    freqs = _ggpu_freqs()
    for name, row in cyc.items():
        pap = PAPER_CYCLES[name]
        emit(f"table3/{name}/riscv", row["riscv"] / RISCV_FREQ,
             f"cycles={row['riscv']} paper_kcycles={pap['riscv']}")
        for ncu in (1, 2, 4, 8):
            emit(f"table3/{name}/{ncu}cu", row[ncu] / freqs[ncu],
                 f"cycles={row[ncu]} paper_kcycles={pap[f'cu{ncu}']} "
                 f"freq={freqs[ncu]:.0f}")


def fig5_speedup(emit):
    """speedup = riscv_cycles * input_ratio / ggpu_cycles (paper's metric),
    plus wall-clock speedup using achieved frequencies."""
    cyc = simulate_all()
    freqs = _ggpu_freqs()
    for name, row in cyc.items():
        r_in, g_in = PAPER_INPUT[name]
        ratio = g_in / r_in
        pap = PAPER_CYCLES[name]
        for ncu in (1, 2, 4, 8):
            su = row["riscv"] * ratio / row[ncu]
            su_wall = su * freqs[ncu] / RISCV_FREQ
            pap_su = pap["riscv"] * ratio / pap[f"cu{ncu}"]
            emit(f"fig5/{name}/{ncu}cu", row[ncu] / freqs[ncu],
                 f"speedup={su:.1f} wallclock={su_wall:.1f} "
                 f"paper={pap_su:.1f}")


def table_memsys(emit, sizes=(64, 1024)):
    """Cache-organization sweep (the engine's third DSE axis): xcorr —
    the kernel whose 8-CU regression the paper attributes to shared-cache
    thrashing — under every registered memory system."""
    from repro.dse import sweep_memsys
    sweep = sweep_memsys(bench="xcorr", n_cus=(1, 2, 8), sizes=sizes)
    base = {c: sweep[(c, "shared")]["cycles"]
            for c in {c for c, _ in sweep}}
    for (c, ms), info in sweep.items():
        emit(f"memsys/xcorr/{ms}/{c}cu", info["time_us"],
             f"cycles={info['cycles']} vs_shared="
             f"{base[c] / info['cycles']:.2f}x "
             f"hits={info['hits']} misses={info['misses']}")


def fig6_area_derated(emit):
    cyc = simulate_all()
    freqs = _ggpu_freqs()
    plans = {c: plan(c, 667.0) for c in (1, 2, 4, 8)}
    for ncu in (1, 2, 4, 8):
        area_ratio = plans[ncu].version.total_area_mm2() / RISCV_AREA_MM2
        sus = []
        pap_sus = []
        for name, row in cyc.items():
            r_in, g_in = PAPER_INPUT[name]
            ratio = g_in / r_in
            sus.append(row["riscv"] * ratio / row[ncu] / area_ratio)
            pap = PAPER_CYCLES[name]
            pap_sus.append(pap["riscv"] * ratio / pap[f"cu{ncu}"])
        gm = float(np.exp(np.mean(np.log(np.maximum(sus, 1e-9)))))
        emit(f"fig6/geomean/{ncu}cu", 0.0,
             f"area_derated_speedup={gm:.2f} area_ratio={area_ratio:.1f} "
             f"(paper best: 10.2 @1cu, worst 5.7 @8cu for parallel kernels)")
        for name, su in zip(cyc, sus):
            emit(f"fig6/{name}/{ncu}cu", 0.0,
                 f"area_derated_speedup={su:.2f}")
