"""Quickstart: build a small model, train a few steps, generate text.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_smoke
from repro.data.pipeline import DataConfig
from repro.optim import adamw
from repro.serve.engine import Engine, EngineConfig
from repro.train.trainer import Trainer, TrainConfig


def main():
    cfg = get_smoke("smollm-360m")
    print(f"arch: {cfg.name}  layers={cfg.n_layers} d_model={cfg.d_model}")

    hp = adamw.AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=60)
    tc = TrainConfig(steps=40, save_every=20, log_every=10,
                     ckpt_dir="/tmp/quickstart_ckpt")
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    trainer = Trainer(cfg, hp, tc, dc)
    result = trainer.run()
    print(f"final loss: {result['final_loss']:.4f}")

    engine = Engine(cfg, result["params"], EngineConfig(slots=2))
    outs = engine.generate([[1, 2, 3], [7, 8]], max_new=8)
    print("generated:", outs)


if __name__ == "__main__":
    main()
