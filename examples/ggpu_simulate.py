"""Run the paper's micro-benchmarks on the simulated G-GPU.

The launch goes through the ``LaunchQueue`` API (``repro.serve.engine``) —
submit a ticket, flush, read the result — the same path a multi-kernel
burst would take (see ``examples/serve_decode.py --ggpu`` for an actual
batched flush).

    PYTHONPATH=src python examples/ggpu_simulate.py --kernel mat_mul --cus 4
    PYTHONPATH=src python examples/ggpu_simulate.py --kernel xcorr \
        --cus 8 --memsys banked
"""
import argparse

import numpy as np

from repro.ggpu.engine import MEMSYS_REGISTRY, GGPUConfig, ScalarConfig, \
    run_kernel
from repro.ggpu.programs import all_benches
from repro.serve.engine import LaunchQueue


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernel", default="mat_mul",
                    choices=sorted(all_benches()))
    ap.add_argument("--cus", type=int, default=4, choices=(1, 2, 4, 8))
    ap.add_argument("--memsys", default="shared",
                    choices=sorted(MEMSYS_REGISTRY))
    ap.add_argument("--fuse", type=int, default=4,
                    help="rounds retired per while_loop iteration")
    args = ap.parse_args()

    b = all_benches()[args.kernel]
    cfg = GGPUConfig(n_cus=args.cus, memsys=args.memsys, fuse=args.fuse)
    print(f"kernel={args.kernel} items={b.gpu_items} CUs={args.cus} "
          f"memsys={args.memsys}")
    queue = LaunchQueue(cfg)
    ticket = queue.submit(b.gpu_prog, b.gpu_mem, b.gpu_items,
                          tag=args.kernel)
    mem, info = queue.flush()[ticket]
    ok = np.array_equal(mem[b.gpu_out], b.ref(b.gpu_mem, b.gpu_n))
    print(f"G-GPU : {info['cycles']:>9d} cycles "
          f"({info['time_us']:.1f} us @500MHz)  "
          f"cache hits/misses={info['hits']}/{info['misses']}  correct={ok}")
    mem, si = run_kernel(b.scalar_prog, b.scalar_mem, 1, ScalarConfig())
    ok = np.array_equal(mem[b.scalar_out], b.ref(b.scalar_mem, b.scalar_n))
    print(f"RISC-V: {si['cycles']:>9d} cycles (input {b.scalar_n} vs "
          f"{b.gpu_n})  correct={ok}")
    ratio = b.gpu_n / b.scalar_n
    print(f"paper-style speed-up (input-scaled): "
          f"{si['cycles'] * ratio / info['cycles']:.1f}x")


if __name__ == "__main__":
    main()
