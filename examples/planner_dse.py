"""GPUPlanner + unified DSE + MeshPlanner walkthrough.

Runs the paper's analytic map, then the unified ``repro.dse`` subsystem:
a joint analytic+cycle-accurate Pareto search that shows which
free-pipelining (analytic-only) picks the simulator rejects.

    PYTHONPATH=src python examples/planner_dse.py
"""
from repro import dse
from repro.configs import get_config
from repro.core.meshplanner import plan as mesh_plan
from repro.core.planner import enumerate_versions, plan
from repro.models.config import SHAPES


def main():
    print("=== GPUPlanner: the paper's map (1 CU @ 667 MHz) ===")
    p = plan(1, 667.0)
    for e in p.map_log:
        print(f"  it{e.iteration}: fmax={e.fmax_mhz:6.0f} MHz "
              f"bottleneck={e.bottleneck:22s} -> {e.action}")
    r = p.version.report()
    print(f"  result: {r['total_area_mm2']} mm^2, {r['n_memory']} memory "
          f"blocks, {r['total_w']} W")

    print("\n=== the paper's failure case: 8 CU @ 667 MHz ===")
    p8 = plan(8, 667.0)
    print(f"  achieved={p8.achieved}: {p8.reason}")

    print("\n=== the 12-version Table I sweep ===")
    for pv in enumerate_versions():
        r = pv.version.report()
        print(f"  {r['n_cus']}CU: fmax={r['fmax_mhz']:6.1f} "
              f"area={r['total_area_mm2']:6.2f}mm^2 mem={r['n_memory']:3d} "
              f"power={r['total_w']:5.2f}W")

    print("\n=== third DSE axis: cache organization (xcorr, reduced) ===")
    for (c, ms), info in dse.sweep_memsys(bench="xcorr", n_cus=(1, 8),
                                          sizes=(32, 256)).items():
        print(f"  {c}CU {ms:10s}: {info['cycles']:>7d} cycles "
              f"hits/misses={info['hits']}/{info['misses']}")

    print("\n=== unified DSE: joint analytic+cycle-accurate Pareto search ===")
    specs = dse.enumerate_specs(cus=(1, 2), freq_targets=(500.0, 667.0,
                                                          750.0))
    res = dse.search(specs=specs,
                     evaluator=dse.Evaluator(benches=("xcorr",),
                                             sizes={"xcorr": (16, 128)}))
    for p, row in zip(res.points, res.report()):
        mark = ("*" if row["on_frontier"] else
                "x" if row["on_analytic_frontier"] else " ")
        print(f"  {mark} {p.label():22s} time={p.time_us:7.1f}us "
              f"(analytic {p.analytic_time_us:6.1f}us) "
              f"area={p.area_mm2:5.2f}mm^2 energy={p.energy_uj:6.1f}uJ")
    print("  * = Pareto frontier; x = analytic-only pick rejected by the")
    print("      cycle model (free-pipelining assumption; see DESIGN.md)")

    print("\n=== MeshPlanner: same loop, TPU pod target ===")
    for arch, shape in [("qwen2-vl-72b", "train_4k"),
                        ("mixtral-8x7b", "train_4k"),
                        ("granite-8b", "decode_32k")]:
        mp = mesh_plan(get_config(arch), SHAPES[shape])
        e = mp.estimate
        print(f"  {arch} x {shape}: fits={mp.fits} knobs=(remat={mp.knobs.remat},"
              f" mb={mp.knobs.microbatches}, fsdp={mp.knobs.fsdp}) "
              f"est {e.total_bytes/2**30:.1f} GiB, bound={e.bound()}")
        for ent in mp.map_log[:-1]:
            print(f"      it{ent.iteration}: {ent.action}")


if __name__ == "__main__":
    main()
