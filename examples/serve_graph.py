"""Device-resident kernel graphs: serve a multi-kernel DAG with zero
host round-trips between stages.

``compile_graph`` splits a traced expression at reduction boundaries
into a 3-stage ``Program`` (map -> segmented reduce -> scale); the
scheduler's dependency-aware planner then folds every instance's stage
into one cohort dispatch and feeds each producer's still-device-resident
output straight into its consumer's staged buffer. The same chains run
again stage-by-stage through the pre-graph idiom (full image download +
host re-staging per edge) for comparison.

    PYTHONPATH=src python examples/serve_graph.py
    PYTHONPATH=src python examples/serve_graph.py --instances 16 --fleet
"""
import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--instances", type=int, default=8, metavar="N",
                    help="independent chains to pipeline (default 8)")
    ap.add_argument("--fleet", action="store_true",
                    help="also route the graph through a 2-device Fleet "
                         "(stages co-locate on one device)")
    args = ap.parse_args()

    import numpy as np

    from repro.compiler import compile_graph
    from repro.ggpu.engine import GGPUConfig
    from repro.serve import (Scheduler, extract_outputs,
                             run_chains_host_staged, submit_programs)

    n, seg = 256, 64
    program = compile_graph(lambda a, b: (a * b).seg_sum(seg) * 3 + 1,
                            {"a": n, "b": n}, name="map_reduce_scale")
    print(f"{program.name}: {len(program.stages)} stages "
          f"({' -> '.join(ck.name for ck in program.stages)})")

    rng = np.random.default_rng(0)
    instances = [{"a": rng.integers(-50, 50, n).astype(np.int32),
                  "b": rng.integers(-50, 50, n).astype(np.int32)}
                 for _ in range(args.instances)]
    refs = [program.reference(inp) for inp in instances]

    cfg = GGPUConfig(n_cus=2)
    pipe = Scheduler(cfg, max_batch=args.instances, max_inflight=8)
    staged = Scheduler(cfg, max_batch=args.instances, max_inflight=8)

    # warm-up: pay the one-time jit compiles on both paths
    submit_programs(pipe, program, instances)
    pipe.drain()
    run_chains_host_staged(staged, program, instances)

    st = pipe.executor.stats
    d0 = st.dispatches
    t0 = time.perf_counter()
    handles = submit_programs(pipe, program, instances)
    outs = extract_outputs(pipe.drain(), handles)
    t_pipe = time.perf_counter() - t0

    t0 = time.perf_counter()
    outs_staged = run_chains_host_staged(staged, program, instances)
    t_staged = time.perf_counter() - t0

    ok = all(np.array_equal(o, r) and np.array_equal(s, r)
             for o, s, r in zip(outs, outs_staged, refs))
    launches = args.instances * len(program.stages)
    print(f"pipelined:   {t_pipe * 1e3:7.2f} ms  "
          f"({st.dispatches - d0} dispatches for {launches} launches)")
    print(f"host-staged: {t_staged * 1e3:7.2f} ms  "
          f"({launches} dispatches, full download per edge)")
    print(f"speedup {t_staged / t_pipe:.2f}x, bit-exact vs reference: {ok}")

    if args.fleet:
        from repro.serve import Fleet, run_program
        fleet = Fleet([("wide", GGPUConfig(n_cus=8)),
                       ("narrow", GGPUConfig(n_cus=1))])
        out = run_program(fleet, program, instances[0])
        print(f"fleet: co-located chain bit-exact: "
              f"{np.array_equal(out, refs[0])} "
              f"(learned service times: {len(fleet._learned)} keys)")


if __name__ == "__main__":
    main()
