"""compile_kernel end to end: DSL -> verified program -> DSE -> fleet.

    PYTHONPATH=src python examples/compile_kernel.py

Compiles a user-written segmented reduction (a workload none of the
hand-written benches cover), differentially verifies it against the
NumPy oracle on several machines, autotunes its lowering schedule,
sweeps it through the unified DSE, and routes a small trace of it (plus
a wide compiled kernel) across the resulting Pareto front with the
serving fleet.
"""
import numpy as np

from repro import dse
from repro.compiler import (SMOKE_SPACE, autotune, codesign, compile_kernel,
                            dsl, kernel_def)
from repro.ggpu.engine import GGPUConfig, ScalarConfig
from repro.serve import Fleet


def main():
    n, seg = 4096, 64
    k = compile_kernel(lambda a, b: ((a - b) * a).seg_sum(seg),
                       dict(a=n, b=n), name="user_segred")
    print(f"compiled {k.name}: {k.prog.shape[0]} SIMT instructions, "
          f"{k.scalar_prog.shape[0]} scalar, {k.n_items} items, "
          f"{k.mem_size} memory words")

    ins = k.random_inputs(seed=0)
    for cfg in (GGPUConfig(n_cus=1), GGPUConfig(n_cus=4)):
        info = k.verify(ins, cfg)
        print(f"  {cfg.n_cus} CU: bit-exact vs oracle, "
              f"{info['cycles']} cycles ({info['time_us']:.1f} us)")
    info = k.verify(ins, ScalarConfig(), scalar=True)
    print(f"  scalar baseline: bit-exact, {info['cycles']} cycles")

    # autotune the lowering schedule: every candidate verified bit-exact
    # against the default kernel's oracle, ranked by true cycles, never
    # worse than the default lowering by construction
    tuned = autotune(lambda a, b: ((a - b) * a).seg_sum(seg),
                     dict(a=n, b=n), GGPUConfig(n_cus=2),
                     name="user_segred")
    print(f"autotune picked {tuned.best_schedule.label()}: "
          f"{tuned.best_cycles} cycles vs {tuned.default_cycles} default "
          f"({tuned.speedup:.2f}x) over {len(tuned.candidates)} candidates")
    r = autotune(*kernel_def("copy", 512), GGPUConfig(n_cus=2),
                 space=SMOKE_SPACE, name="copy")
    print(f"  copy@512: {r.best_schedule.label()} {r.best_cycles} vs "
          f"{r.default_cycles} default (coarsening amortizes the TID "
          f"prologue)")

    # co-design: (DesignPoint, Schedule) pairs on one Pareto frontier
    cod = codesign({m: kernel_def(m, 256) for m in ("copy", "vec_mul")},
                   space=SMOKE_SPACE, cus=(1, 2),
                   freq_targets=(500.0, 667.0))
    print("co-designed frontier (hardware point | schedule):")
    for jp in cod.frontier:
        print(f"  {jp.label():32s} {jp.point.time_us:8.2f} us  "
              f"{jp.point.area_mm2:6.2f} mm^2")

    # the compiled kernel as a first-class DSE workload
    res = dse.search(
        specs=dse.enumerate_specs(cus=(1, 2, 4),
                                  freq_targets=(500.0, 667.0)),
        evaluator=dse.Evaluator(benches=(),
                                workloads={"user_segred": k.as_bench()},
                                check=True))
    print("DSE frontier over the compiled workload:")
    for p in res.frontier:
        print(f"  {p.label():24s} {p.time_us:8.2f} us  "
              f"{p.area_mm2:6.2f} mm^2")

    # route a mixed compiled trace across the frontier ends
    wide = compile_kernel(
        lambda x: dsl.stencil(x, [1, -2, 1], [-1, 0, 1]),
        dict(x=8 * 4096), name="laplace")
    front = sorted(res.frontier, key=lambda p: p.area_mm2)
    fleet = Fleet([(p.label(), p.point.config)
                   for p in (front[0], front[-1])])
    w_ins = wide.random_inputs(seed=1)
    for _ in range(3):
        fleet.submit(k.prog, k.build_mem(ins), k.n_items, tag="segred")
        fleet.submit(wide.prog, wide.build_mem(w_ins), wide.n_items,
                     tag="laplace")
    results = fleet.drain()
    for r in results:
        want = (k if r.info["tag"] == "segred" else wide)
        np.testing.assert_array_equal(
            r.mem[want.out], want.reference(ins if r.info["tag"] ==
                                            "segred" else w_ins))
    print(f"fleet routed {len(results)} compiled launches bit-exactly: "
          f"{fleet.report()['placement']}")


if __name__ == "__main__":
    main()
