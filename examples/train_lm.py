"""End-to-end training driver: train a ~100M-class model for a few hundred
steps with checkpointing and resume.

    PYTHONPATH=src python examples/train_lm.py --arch smollm-360m \
        --steps 300 --d-model 512 --layers 8

Any assigned architecture id works (--arch); by default a width/depth-
reduced variant of it is trained so the run fits a CPU box. Kill it at any
point and re-run: it resumes from the last checkpoint, bit-identically.
"""
import argparse

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import DataConfig
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--heads", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/train_lm_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch).replace(
        n_layers=args.layers, d_model=args.d_model, n_heads=args.heads,
        n_kv_heads=max(1, args.heads // 2), d_ff=4 * args.d_model
        if get_config(args.arch).d_ff else 0,
        vocab_size=args.vocab, head_dim=0, lru_width=0,
        window=min(get_config(args.arch).window, args.seq_len)
        if get_config(args.arch).window else 0)
    n_params = cfg.n_params()
    print(f"training {cfg.name}-reduced: {n_params/1e6:.1f}M params")

    hp = adamw.AdamWConfig(lr=args.lr, warmup_steps=30,
                           total_steps=args.steps, weight_decay=0.1)
    tc = TrainConfig(steps=args.steps, save_every=100, log_every=10,
                     ckpt_dir=args.ckpt_dir)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                    global_batch=args.batch)
    result = Trainer(cfg, hp, tc, dc).run()
    print(f"done: final loss {result['final_loss']:.4f} "
          f"after {result['steps']} steps")


if __name__ == "__main__":
    main()
