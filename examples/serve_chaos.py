"""Deterministic chaos serving: replay a fault scenario against a
self-healing fleet and check every served result against the fault-free
oracle.

A ``FAULTS`` scenario bundles a seed-keyed :class:`FaultPlan` (SEU bit
flips, stragglers, wedged devices) with the resilience machinery that
answers it — checksum audits + bounded retries, executor timeouts,
eviction, and deadline-aware hedging. Same seed, same trace => the
byte-identical injection decision log and the same served bits.

    PYTHONPATH=src python examples/serve_chaos.py
    PYTHONPATH=src python examples/serve_chaos.py --faults device-loss
    PYTHONPATH=src python examples/serve_chaos.py --faults straggler --n 12
"""
import argparse
import collections
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--faults", default="seu", metavar="SCENARIO",
                    help="FAULTS scenario to replay (default seu; see "
                         "`python -m repro.registry --json`)")
    ap.add_argument("--n", type=int, default=16, metavar="N",
                    help="requests to serve under chaos (default 16)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rate", type=float, default=None,
                    help="override the scenario's injection rate")
    args = ap.parse_args()

    import numpy as np

    from repro.ggpu import programs
    from repro.ggpu.engine import GGPUConfig, run_kernel
    from repro.registry import FAULTS
    from repro.serve import Fleet, Request
    from repro.serve.request import result_checksum

    b = programs._vec_mul(16, 64)
    cfg = GGPUConfig(n_cus=2)
    rng = np.random.default_rng(args.seed)
    mems = [rng.integers(-30, 30, b.gpu_mem.shape[0]).astype(np.int32)
            for _ in range(args.n)]
    refs = [run_kernel(b.gpu_prog, m, b.gpu_items, cfg)[0] for m in mems]

    kw = {} if args.rate is None else {"rate": args.rate}
    if args.faults == "device-loss":
        kw["stuck_after"] = 0            # dev0 wedges on its 1st dispatch
    elif args.faults == "straggler" and args.rate is None:
        kw["rate"] = 0.5                 # demo-sized trace: make it land
    sc = FAULTS.get(args.faults)(seed=args.seed, **kw)
    fleet = Fleet([("dev0", cfg), ("dev1", GGPUConfig(n_cus=1))],
                  max_batch=2, **sc.fleet_kwargs())
    for m, ref in zip(mems, refs):
        # the audit is what makes post-compute corruption detectable
        audit = result_checksum(ref) if sc.audit else None
        fleet.submit_request(Request(b.gpu_prog, m, b.gpu_items,
                                     audit=audit))

    t0 = time.perf_counter()
    results = fleet.drain()
    wall = time.perf_counter() - t0

    served_ok = sum(np.array_equal(r.mem, refs[r.info["ticket"]])
                    for r in results)
    kinds = collections.Counter(e[0] for e in sc.decision_log())
    rep = fleet.report()
    print(f"scenario {args.faults!r} seed {args.seed}: "
          f"{len(results)}/{args.n} served in {wall * 1e3:.1f} ms")
    print(f"  injected: {dict(kinds) or 'nothing'}")
    print(f"  bit-exact vs fault-free oracle: {served_ok}/{len(results)}")
    print(f"  quarantined: {sorted(fleet.quarantined) or 'none'}")
    print(f"  devices: {rep['device_state']}  health {rep['health']}")
    print(f"  reroutes {rep.get('reroutes', 0)}, "
          f"hedged {rep.get('hedged', 0)}")
    # determinism: the decision log is a pure function of (seed, plan,
    # trace) — rerun with the same --seed and diff this line
    print(f"  decision log ({len(sc.decision_log())} entries): "
          f"{sc.decision_log()[:3]}{' ...' if kinds.total() > 3 else ''}")


if __name__ == "__main__":
    main()
