"""Batched serving example: prefill + slot-batched decode on any arch, and
the same continuous-batching idea applied to G-GPU kernel launches — plus
the fleet router serving a mixed trace across two DSE-selected configs.

    PYTHONPATH=src python examples/serve_decode.py --arch granite-8b
    PYTHONPATH=src python examples/serve_decode.py --ggpu 6
    PYTHONPATH=src python examples/serve_decode.py --fleet 4
"""
import argparse
import time


def serve_llm(args):
    import jax

    from repro.configs import ARCH_IDS, get_smoke
    from repro.models.schema import init_params
    from repro.serve import Engine, EngineConfig

    cfg = get_smoke(args.arch)
    if cfg.is_encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params,
                    EngineConfig(slots=3, temperature=args.temperature))
    prompts = [[1, 5, 9], [2, 4], [10, 11, 12, 13], [3]]
    outs = engine.generate(prompts, max_new=args.max_new)
    for p, o in zip(prompts, outs):
        print(f"prompt {p} -> {o[len(p):]}")


def serve_ggpu(n_requests: int):
    """A burst of G-GPU kernel launch requests served through the
    continuous-batching Scheduler: same-shape launches ride one cohort
    stepper call, and submissions interleave with incremental drains."""
    import numpy as np

    from repro.ggpu import programs
    from repro.ggpu.engine import GGPUConfig
    from repro.serve import Scheduler

    cfg = GGPUConfig(n_cus=2)
    b = programs._vec_mul(64, 2048)
    rng = np.random.default_rng(0)
    sched = Scheduler(cfg)

    def submit_burst():
        refs = {}
        for i in range(n_requests):
            mem0 = np.concatenate([
                rng.integers(-100, 100, 2 * 2048).astype(np.int32),
                np.zeros(2048, np.int32)])
            t = sched.submit(b.gpu_prog, mem0, b.gpu_items, tag=f"req{i}")
            refs[t] = b.ref(mem0, 2048)
        return refs

    submit_burst()
    sched.drain()                 # warm-up: pay the one-time jit compile
    refs = submit_burst()
    st = sched.executor.stats
    l0, d0, h0 = st.launches, st.dispatches, st.trace_hits
    t0 = time.perf_counter()
    results = sched.drain()
    dt = time.perf_counter() - t0
    for res in results:
        t = res.info["ticket"]
        ok = np.array_equal(res.mem[b.gpu_out], refs[t])
        print(f"{res.info['tag']}: cycles={res.info['cycles']} "
              f"batch={res.info['batch_size']} correct={ok}")
    # deltas over the measured burst only (warm-up compile excluded)
    dispatches = st.dispatches - d0
    print(f"served {n_requests} launches in {dt * 1e3:.1f} ms "
          f"(occupancy {(st.launches - l0) / dispatches:.1f} "
          f"launches/dispatch, trace-cache hit rate "
          f"{(st.trace_hits - h0) / dispatches:.0%}; compile excluded)")


def serve_fleet(n_bursts: int):
    """Route a mixed wide+narrow trace across the two ends of a DSE Pareto
    front and compare against pinning everything to one config."""
    import numpy as np

    from repro import dse
    from repro.ggpu import programs
    from repro.serve import Fleet, pinned_makespan

    res = dse.search(specs=dse.enumerate_specs(cus=(1, 8),
                                               freq_targets=(667.0,)),
                     evaluator=dse.Evaluator(benches=("xcorr",),
                                             sizes={"xcorr": (16, 128)}))
    frontier = sorted(res.frontier, key=lambda p: p.time_us)
    if frontier[0] is frontier[-1]:
        raise SystemExit("DSE frontier collapsed to one design: nothing to "
                         "route across — widen the spec grid")
    devices = [(p.label(), p.point.config)
               for p in (frontier[0], frontier[-1])]
    print("fleet devices:", " + ".join(name for name, _ in devices))

    wide = programs._copy(16, 1024)          # W=16: wants CUs
    narrow = programs._reduction(64, 256)    # W=1: wants clock
    rng = np.random.default_rng(0)
    trace = []
    for _ in range(n_bursts):
        for b in (wide, narrow):
            mem0 = rng.integers(-50, 50, b.gpu_mem.shape[0]).astype(np.int32)
            trace.append((b.gpu_prog, mem0, b.gpu_items))

    fleet = Fleet(devices)
    for prog, mem0, n_items in trace:
        fleet.submit(prog, mem0, n_items)
    fleet.drain()
    rep = fleet.report()
    print(f"placement: {rep['placement']}")
    print(f"fleet makespan: {rep['makespan_us']:.1f} us (modeled)")
    for name, cfg in devices:
        print(f"pinned to {name}: {pinned_makespan(cfg, trace):.1f} us")


def main():
    from repro.configs import ARCH_IDS

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=ARCH_IDS)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--ggpu", type=int, default=0, metavar="N",
                    help="serve N G-GPU kernel launches instead of LLM decode")
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="serve N mixed bursts across a 2-config DSE fleet")
    args = ap.parse_args()

    if args.fleet:
        serve_fleet(args.fleet)
    elif args.ggpu:
        serve_ggpu(args.ggpu)
    else:
        serve_llm(args)


if __name__ == "__main__":
    main()
