"""Batched serving example: prefill + slot-batched decode on any arch.

    PYTHONPATH=src python examples/serve_decode.py --arch granite-8b
"""
import argparse

import jax

from repro.configs import ARCH_IDS, get_smoke
from repro.models.schema import init_params
from repro.serve.engine import Engine, EngineConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=ARCH_IDS)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    if cfg.is_encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params,
                    EngineConfig(slots=3, temperature=args.temperature))
    prompts = [[1, 5, 9], [2, 4], [10, 11, 12, 13], [3]]
    outs = engine.generate(prompts, max_new=args.max_new)
    for p, o in zip(prompts, outs):
        print(f"prompt {p} -> {o[len(p):]}")


if __name__ == "__main__":
    main()
