"""Batched serving example: prefill + slot-batched decode on any arch, and
the same continuous-batching idea applied to G-GPU kernel launches.

    PYTHONPATH=src python examples/serve_decode.py --arch granite-8b
    PYTHONPATH=src python examples/serve_decode.py --ggpu 6
"""
import argparse
import time


def serve_llm(args):
    import jax

    from repro.configs import ARCH_IDS, get_smoke
    from repro.models.schema import init_params
    from repro.serve.engine import Engine, EngineConfig

    cfg = get_smoke(args.arch)
    if cfg.is_encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params,
                    EngineConfig(slots=3, temperature=args.temperature))
    prompts = [[1, 5, 9], [2, 4], [10, 11, 12, 13], [3]]
    outs = engine.generate(prompts, max_new=args.max_new)
    for p, o in zip(prompts, outs):
        print(f"prompt {p} -> {o[len(p):]}")


def serve_ggpu(n_requests: int):
    """A burst of G-GPU kernel launch requests served through the batched
    LaunchQueue: same-shape launches ride one vmapped stepper call."""
    import numpy as np

    from repro.ggpu import programs
    from repro.ggpu.engine import GGPUConfig
    from repro.serve.engine import LaunchQueue

    cfg = GGPUConfig(n_cus=2)
    b = programs._vec_mul(64, 2048)
    rng = np.random.default_rng(0)
    queue = LaunchQueue(cfg)

    def submit_burst():
        refs = []
        for i in range(n_requests):
            mem0 = np.concatenate([
                rng.integers(-100, 100, 2 * 2048).astype(np.int32),
                np.zeros(2048, np.int32)])
            queue.submit(b.gpu_prog, mem0, b.gpu_items, tag=f"req{i}")
            refs.append(b.ref(mem0, 2048))
        return refs

    submit_burst()
    queue.flush()                 # warm-up: pay the one-time jit compile
    refs = submit_burst()
    t0 = time.perf_counter()
    results = queue.flush()
    dt = time.perf_counter() - t0
    for i, ((mem, info), ref) in enumerate(zip(results, refs)):
        ok = np.array_equal(mem[b.gpu_out], ref)
        print(f"req{i}: cycles={info['cycles']} "
              f"batch={info['batch_size']} correct={ok}")
    print(f"served {n_requests} launches in {dt * 1e3:.1f} ms "
          f"(one compiled stepper, batched; compile excluded)")


def main():
    from repro.configs import ARCH_IDS

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=ARCH_IDS)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--ggpu", type=int, default=0, metavar="N",
                    help="serve N G-GPU kernel launches instead of LLM decode")
    args = ap.parse_args()

    if args.ggpu:
        serve_ggpu(args.ggpu)
    else:
        serve_llm(args)


if __name__ == "__main__":
    main()
